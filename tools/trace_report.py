"""Render structured JSONL event logs as per-trace waterfalls.

Consumes the files written by ``repro.obs.trace`` (replica request logs,
``fit(event_log=...)`` training logs, ``REPRO_OBS_LOG``) and prints:

  * a **per-trace waterfall** — every event carrying a trace ID, ordered by
    timestamp, with millisecond offsets from the trace's first event, so one
    request can be followed transport -> admission -> engine span -> reply
    (and, for appends, into the refresh that folded them in);
  * a **residual-decay summary** — for ``solve_step`` events that carry the
    solver ring (``SolverConfig.record_history``), the per-step first/last
    residual, the decay factor, and a coarse log10 sparkline of the
    trajectory; plus the closing ``fit_done`` totals;
  * a **budget-decision table** — for adaptive fits
    (``fit(budget_policy=...)``), the per-step ``budget_decision`` events
    rendered row-for-row with the ``solve_step`` table (same step/lane
    keys): allocated vs realised epochs, end residual, the calibrated
    decay rate, and the pool remaining (schema: ``docs/adaptive.md``).

Stdlib only, read-only, tolerant of truncated tail lines (a live log can be
mid-write).

``--fleet DIR`` merges every ``*.jsonl`` under DIR — per-replica request
logs AND the fleet monitor's alert log — into one time-ordered stream: the
waterfall keys on trace IDs where present, and a **fleet timeline** section
renders the monitor's ``slo_alert`` transitions (OK/WARN/PAGE, burn rates)
against the surrounding request activity.

Usage:
    python tools/trace_report.py [LOG.jsonl ...] [--fleet DIR]
        [--trace ID] [--kind KIND] [--limit N]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# Fields already rendered in an event's fixed columns — everything else is
# shown as trailing key=value detail.
_SHOWN = {"ts", "kind", "trace_id", "dur_ms", "res_history"}
_SPARK = "▁▂▃▄▅▆▇█"


def load_events(paths):
    """All parseable events from ``paths``, each tagged with its source file."""
    events = []
    for path in paths:
        try:
            with open(path) as f:
                lines = f.readlines()
        except OSError as e:
            print(f"[trace-report] skipping {path}: {e}", file=sys.stderr)
            continue
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError:
                continue  # live log mid-write: the tail line may be partial
            if isinstance(ev, dict) and "ts" in ev and "kind" in ev:
                ev["_src"] = path
                events.append(ev)
    events.sort(key=lambda e: e["ts"])
    return events


def _detail(ev) -> str:
    parts = []
    for k, v in ev.items():
        if k in _SHOWN or k.startswith("_") or v is None:
            continue
        if isinstance(v, float):
            v = f"{v:.4g}"
        parts.append(f"{k}={v}")
    return " ".join(parts)


def _sparkline(values) -> str:
    """Coarse log-scale sparkline (empty for <2 finite positive points)."""
    import math

    logs = [math.log10(v) for v in values if v and v > 0]
    if len(logs) < 2:
        return ""
    lo, hi = min(logs), max(logs)
    span = (hi - lo) or 1.0
    idx = [int((x - lo) / span * (len(_SPARK) - 1)) for x in logs]
    return "".join(_SPARK[i] for i in idx)


def print_waterfall(events, trace=None, limit=0):
    """One block per trace ID, events offset in ms from the trace's start."""
    traces: dict = {}
    for ev in events:
        tid = ev.get("trace_id")
        if tid is None or (trace is not None and tid != trace):
            continue
        traces.setdefault(tid, []).append(ev)
    if not traces:
        print("no traced events" + (f" for trace {trace!r}" if trace else ""))
        return
    shown = 0
    for tid, evs in traces.items():
        if limit and shown >= limit:
            print(f"... {len(traces) - shown} more traces (raise --limit)")
            break
        shown += 1
        t0 = evs[0]["ts"]
        span_ms = (evs[-1]["ts"] - t0) * 1e3
        print(f"trace {tid}  ({len(evs)} events, {span_ms:.1f}ms)")
        for ev in evs:
            off = (ev["ts"] - t0) * 1e3
            dur = ev.get("dur_ms")
            dur_s = f" [{dur:.2f}ms]" if isinstance(dur, (int, float)) else ""
            print(f"  +{off:9.2f}ms  {ev['kind']:<10}{dur_s:<12} "
                  f"{_detail(ev)}")
        print()


def print_residual_summary(events):
    """Convergence table from solve_step rings + the fit_done totals."""
    steps = [e for e in events if e["kind"] == "solve_step"]
    if steps:
        print("residual decay (solve_step):")
        print(f"  {'step':>4} {'solver':<6} {'lane':>4} {'iters':>5} "
              f"{'first_res':>10} {'last_res':>10} {'decay':>9}  trajectory")
        for ev in steps:
            ring = ev.get("res_history") or []
            res = [row[0] for row in ring if isinstance(row, (list, tuple))]
            first = res[0] if res else ev.get("res_y")
            last = res[-1] if res else ev.get("res_y")
            decay = (last / first) if first else float("nan")
            lane = ev.get("lane")
            print(f"  {ev.get('step', -1):>4} {ev.get('solver', '?'):<6} "
                  f"{'-' if lane is None else lane:>4} "
                  f"{ev.get('iters', 0):>5} {first:>10.3e} {last:>10.3e} "
                  f"{decay:>9.2e}  {_sparkline(res)}")
    print_budget_summary(events)
    for ev in events:
        if ev["kind"] == "fit_done":
            print(f"fit_done: solver={ev.get('solver')} "
                  f"steps={ev.get('num_steps')} iters={ev.get('total_iters')} "
                  f"epochs={ev.get('total_epochs'):.1f} "
                  f"wall={ev.get('wall_time_s'):.2f}s "
                  f"solver_time={ev.get('solver_time_s'):.2f}s")


def fleet_logs(fleet_dir):
    """Every ``*.jsonl`` under ``fleet_dir`` (one level), sorted.

    The layout ``--request-log`` + ``--monitor`` produce: per-replica
    ``replica_*.jsonl`` request logs next to the monitor's
    ``monitor.jsonl`` alert log.
    """
    return sorted(glob.glob(os.path.join(fleet_dir, "*.jsonl")))


def print_fleet_timeline(events, limit=0):
    """The fleet view: ``slo_alert`` transitions in request context.

    Renders every monitor alert (state change, burn rates) in one
    time-ordered table, each annotated with how many requests landed in
    the preceding inter-alert gap — enough to read "traffic stopped, then
    availability paged" straight off the report. Traced request detail
    stays in the per-trace waterfall above.
    """
    alerts = [e for e in events if e["kind"] == "slo_alert"]
    if not alerts:
        return
    requests = [e["ts"] for e in events if e["kind"] == "request"]
    t0 = events[0]["ts"]
    print(f"fleet timeline ({len(alerts)} alert(s), "
          f"{len(requests)} request(s)):")
    prev = t0
    shown = 0
    for ev in alerts:
        if limit and shown >= limit:
            print(f"  ... {len(alerts) - shown} more alerts (raise --limit)")
            break
        shown += 1
        n_req = sum(1 for ts in requests if prev <= ts < ev["ts"])
        burns = ev.get("burn_rates") or {}
        burn_s = " ".join(f"{k}={v:.3g}" for k, v in sorted(burns.items()))
        print(f"  +{(ev['ts'] - t0) * 1e3:9.1f}ms  "
              f"{ev.get('slo', '?'):<14} "
              f"{ev.get('from_state', '?'):>4} -> {ev.get('to_state', '?'):<4} "
              f"({n_req} requests since last alert) {burn_s}")
        prev = ev["ts"]
    print()


def print_budget_summary(events):
    """Adaptive-controller table from ``budget_decision`` events.

    Rows carry the same ``(step, lane)`` keys as the ``solve_step`` table
    above them, so the two read side by side: what the controller
    allocated, what the solve realised, and the calibrated state it left
    behind.
    """
    decisions = [e for e in events if e["kind"] == "budget_decision"]
    if not decisions:
        return

    def f(ev, key, width=9):
        v = ev.get(key)
        return f"{v:>{width}.3g}" if isinstance(v, (int, float)) else \
            f"{'-':>{width}}"

    print("budget decisions (budget_decision):")
    print(f"  {'step':>4} {'solver':<6} {'lane':>4} {'alloc':>9} "
          f"{'realised':>9} {'pred_tol':>9} {'res':>9} {'slope':>9} "
          f"{'pool':>9}")
    for ev in decisions:
        lane = ev.get("lane")
        print(f"  {ev.get('step', -1):>4} {ev.get('solver', '?'):<6} "
              f"{'-' if lane is None else lane:>4} {f(ev, 'alloc')} "
              f"{f(ev, 'realised')} {f(ev, 'pred_to_tol')} {f(ev, 'res')} "
              f"{f(ev, 'slope')} {f(ev, 'pool')}")


def main(argv=None):
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("logs", nargs="*", help="JSONL event logs")
    ap.add_argument("--fleet", default=None, metavar="DIR",
                    help="merge every *.jsonl under DIR (replica request "
                         "logs + the monitor's alert log) and render the "
                         "fleet timeline")
    ap.add_argument("--trace", default=None,
                    help="show only this trace ID's waterfall")
    ap.add_argument("--kind", default=None,
                    help="keep only events of this kind")
    ap.add_argument("--limit", type=int, default=20,
                    help="max traces in the waterfall (0 = all)")
    args = ap.parse_args(argv)

    paths = list(args.logs)
    if args.fleet:
        found = fleet_logs(args.fleet)
        if not found:
            print(f"no *.jsonl logs under {args.fleet}", file=sys.stderr)
        paths.extend(found)
    if not paths:
        ap.error("no logs given (pass LOG.jsonl files and/or --fleet DIR)")

    events = load_events(paths)
    if args.kind:
        events = [e for e in events if e["kind"] == args.kind]
    if not events:
        print("no events parsed")
        return 1
    kinds: dict = {}
    for e in events:
        kinds[e["kind"]] = kinds.get(e["kind"], 0) + 1
    print(f"{len(events)} events from {len(paths)} log(s): "
          + ", ".join(f"{k}={n}" for k, n in sorted(kinds.items())))
    print()
    if args.fleet:
        print_fleet_timeline(events, limit=args.limit)
    print_waterfall(events, trace=args.trace, limit=args.limit)
    print_residual_summary(events)
    return 0


if __name__ == "__main__":
    sys.exit(main())
