"""Solver-config-grid sweeps: traced numerics vs per-cell compiles, and
lane sharding across 1 vs N virtual devices.

Two A/Bs over the SAME seed x tolerance x lr grid (8 cells, one kernel,
SGD — a sweep over the paper's early-stopping/budget knobs):

  1. grouped-traced-numerics (one process, ONE executable for the whole
     numeric grid: tolerance/lr ride as a lane-stacked SolverNumerics) vs
     ``--isolate`` (one subprocess AND one executable per cell — the
     compile cost the traced path amortises away). Asserts the grouped
     path compiled exactly once and is >= 2x faster end-to-end.
  2. the same grouped sweep with ``--shard-lanes`` on 1 vs 8 virtual host
     devices (``XLA_FLAGS=--xla_force_host_platform_device_count``), with
     cell-level parity asserted across the two runs. Virtual CPU devices
     share the same physical cores, so the wall-clock ratio is REPORTED
     but not asserted — on real accelerators each device is real silicon
     and this ratio is the point of the lane mesh.

Each timed run is a fresh top-level process so interpreter + jax startup
is charged where it is actually paid. Writes BENCH_sharded_sweep.json.

    PYTHONPATH=src python benchmarks/sharded_sweep.py [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KERNEL = "matern32"
SEEDS = 2
TOLS = "0.05,0.01"
LRS = "0.5,1.0"  # x 2 seeds = 8 lanes, one static group
MIN_SPEEDUP = 2.0
SHARD_DEVICES = 8


def _run_sweep(out_dir: str, max_n: int, steps: int, isolate: bool = False,
               shard: bool = False, devices: int = 0) -> float:
    cmd = [
        sys.executable, "-m", "repro.launch.batch",
        "--out", out_dir, "--dataset", "pol", "--max-n", str(max_n),
        "--kernels", KERNEL, "--seeds", str(SEEDS), "--steps", str(steps),
        "--smoke", "--solver", "sgd", "--tolerances", TOLS,
        "--sgd-lrs", LRS,
    ]
    if isolate:
        cmd.append("--isolate")
    if shard:
        cmd.append("--shard-lanes")
    src = os.path.join(REPO, "src")
    inherited = os.environ.get("PYTHONPATH")
    env = {**os.environ, "PYTHONPATH":
           src + (os.pathsep + inherited if inherited else "")}
    if devices:
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={devices}"
        ).strip()
    t0 = time.perf_counter()
    r = subprocess.run(cmd, capture_output=True, text=True, cwd=REPO,
                       env=env, timeout=3600)
    dt = time.perf_counter() - t0
    if r.returncode != 0:
        raise RuntimeError(
            f"sweep failed ({cmd}):\n{(r.stderr or r.stdout)[-3000:]}"
        )
    return dt


def _cells(out_dir: str) -> dict:
    cells = {}
    for name in os.listdir(out_dir):
        if name.startswith("_"):
            continue
        with open(os.path.join(out_dir, name)) as f:
            cells[name] = json.load(f)
    return cells


def csv_line(name: str, value: float, derived: str):
    print(f"{name},{value:.1f},{derived}")


def main(small: bool = True, out_dir: str = "artifacts/bench"):
    max_n, steps = (256, 3) if small else (512, 5)
    with tempfile.TemporaryDirectory() as d_grp, \
            tempfile.TemporaryDirectory() as d_iso, \
            tempfile.TemporaryDirectory() as d_s1, \
            tempfile.TemporaryDirectory() as d_s8:
        t_grouped = _run_sweep(d_grp, max_n, steps)
        t_isolated = _run_sweep(d_iso, max_n, steps, isolate=True)
        with open(os.path.join(d_grp, "_sweep_status.json")) as f:
            status = json.load(f)

        t_shard1 = _run_sweep(d_s1, max_n, steps, shard=True, devices=1)
        t_shard8 = _run_sweep(d_s8, max_n, steps, shard=True,
                              devices=SHARD_DEVICES)
        with open(os.path.join(d_s8, "_sweep_status.json")) as f:
            status8 = json.load(f)
        cells1, cells8 = _cells(d_s1), _cells(d_s8)

    # cell-level parity between the 1-device and 8-device sharded runs
    assert sorted(cells1) == sorted(cells8), (sorted(cells1), sorted(cells8))
    max_dev = 0.0
    for name, rec in cells1.items():
        a = rec["final_hypers"]
        b = cells8[name]["final_hypers"]
        denom = max(max(abs(v) for v in a), 1e-6)
        max_dev = max(max_dev, max(abs(p - q) for p, q in zip(a, b)) / denom)
    assert max_dev < 1e-3, f"1-vs-8-device hypers deviate: {max_dev}"

    grid_speedup = t_isolated / t_grouped
    shard_speedup = t_shard1 / t_shard8
    report = {
        "bench": "sharded_sweep",
        "grid": {"kernel": KERNEL, "seeds": SEEDS,
                 "tolerances": TOLS.split(","), "lrs": LRS.split(","),
                 "max_n": max_n, "steps": steps},
        "lanes": status["cells"],
        "groups": status["groups"],
        "num_compiles": status["num_compiles"],
        "wall_grouped_s": t_grouped,
        "wall_isolated_s": t_isolated,
        "grid_speedup": grid_speedup,
        "wall_shard1_s": t_shard1,
        "wall_shard8_s": t_shard8,
        "shard_devices": status8["shard_devices"],
        "shard_speedup": shard_speedup,
        "shard_parity_max_rel_dev": max_dev,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_sharded_sweep.json"), "w") as f:
        json.dump(report, f, indent=2)

    csv_line("sharded_sweep_grouped_numerics", t_grouped * 1e6,
             f"lanes={status['cells']} groups={status['groups']} "
             f"compiles={status['num_compiles']}")
    csv_line("sharded_sweep_per_cell_compiles", t_isolated * 1e6,
             f"cells={status['cells']}")
    csv_line("sharded_sweep_grid_speedup", grid_speedup,
             "x (per-cell / traced-numerics)")
    csv_line("sharded_sweep_1_device", t_shard1 * 1e6, "sharded, 1 device")
    csv_line("sharded_sweep_8_devices", t_shard8 * 1e6,
             f"sharded, {SHARD_DEVICES} virtual devices "
             f"(parity {max_dev:.1e})")
    csv_line("sharded_sweep_device_speedup", shard_speedup,
             "x (1 / 8 virtual CPU devices; informational)")

    assert status["cells"] == 2 * SEEDS * 2, status
    assert status["num_compiles"] == status["groups"] == 1, status
    assert status8["shard_devices"] == SHARD_DEVICES, status8
    assert grid_speedup >= MIN_SPEEDUP, (
        f"traced-numerics sweep only {grid_speedup:.2f}x faster than "
        f"per-cell compiles (need >= {MIN_SPEEDUP}x): "
        f"grouped={t_grouped:.1f}s isolated={t_isolated:.1f}s"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()
    main(small=not args.full, out_dir=args.out)
