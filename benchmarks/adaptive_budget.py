"""Adaptive vs fixed solver budgets: the A/B behind the adaptive controller.

The fig9 grid fixes one epoch budget per outer MLL step for the whole fit;
the adaptive controller (``repro.solvers.adaptive``) instead calibrates a
log-linear decay model from each solve's residual ring and allocates per
step — a few epochs mid-trajectory (solving far below the residual
re-inflation the next Adam update injects is wasted work), annealing back
to full to-tolerance solves by the horizon so the final residual matches.

One A/B on the fig9 configuration (CG, pathwise + warm start — the paper's
best combination): a grid of fixed per-step budgets plus an unlimited
to-tolerance arm, against a single adaptive arm. All arms share dataset,
seed, solver and estimator; fixed arms run with telemetry off (their
compiled programs are bit-identical to the pre-telemetry build), the
adaptive arm records a ``record_history``-deep residual ring.

Asserted (the tentpole's acceptance bars):

  * the adaptive arm converges: ``final_res_z <= tolerance``;
  * every fixed arm that reaches an equal-or-better final ``res_z``
    (``<= max(tolerance, adaptive final res_z)``) spends >= 1.5x the
    adaptive arm's cumulative epochs — i.e. adaptive beats the BEST fixed
    budget 1.5x at matched solution quality (at least one fixed arm must
    match: the to-tolerance arm always does);
  * ZERO steady-state retraces: a second adaptive fit with a different
    seed and different (traced) policy coefficients adds no ``outer_scan``
    cache entries.

Emits ``BENCH_adaptive_budget.json`` (merged by ``benchmarks/run.py``) and
the ``name,us_per_call,derived`` CSV lines the runner parses.

Run: PYTHONPATH=src python benchmarks/adaptive_budget.py [--smoke|--full]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import bench_dataset, csv_line, run_variant  # noqa: E402

from repro.core.outer import outer_scan  # noqa: E402
from repro.solvers import make_budget_policy  # noqa: E402

# Required headline: adaptive must spend >= this factor fewer cumulative
# epochs than the best quality-matched fixed budget.
MIN_EPOCH_RATIO = 1.5

# Tight enough that to-tolerance solves genuinely cost epochs per step
# (at 1e-2 the fig9 toy problems converge in 1-2 CG iterations and every
# budget arm degenerates to the same cost).
TOLERANCE = 1e-3
RECORD_HISTORY = 16


def _scan_cache_size() -> int:
    """Compiled-variant count of the shared outer_scan executable."""
    try:
        return int(outer_scan._cache_size())
    except Exception:  # pragma: no cover - private jit API moved
        return -1


def _arm_row(name: str, r: dict, steps: int) -> dict:
    return {
        "name": name,
        "budget": r["budget"],
        "cum_epochs": float(r["cum_epochs"][-1]),
        "final_res_z": r["final_res_z"],
        "final_res_y": r["final_res_y"],
        "mean_res_z": r["mean_res_z"],
        "test_llh": r.get("test_llh"),
        "us_per_step": r["total_time_s"] * 1e6 / steps,
    }


def main(small: bool = True, out_dir: str = "artifacts/bench",
         smoke: bool = False):
    if smoke:  # CI tier: same arms and asserts, paper-scale -> minutes
        max_n, steps, probes = 400, 24, 16
        fixed_budgets = (3.0, 5.0, 10.0, 0.0)
    elif small:
        max_n, steps, probes = 800, 24, 32
        fixed_budgets = (3.0, 5.0, 7.0, 10.0, 0.0)
    else:
        max_n, steps, probes = 4000, 50, 32
        fixed_budgets = (3.0, 5.0, 10.0, 20.0, 50.0, 0.0)
    ds = bench_dataset("pol", max_n=max_n)

    # Preconditioning off, as in online_bo: at benchmark sizes a rank-100
    # preconditioner is essentially exact and would flatten the budget
    # differences the A/B is about.
    kw = dict(steps=steps, probes=probes, precond_rank=0,
              tolerance=TOLERANCE)

    arms = {}
    for b in fixed_budgets:
        tag = f"b{b:g}" if b > 0 else "to-tol"
        r = run_variant(ds, "cg", pathwise=True, warm=True, budget=b, **kw)
        arms[tag] = _arm_row(f"adaptive_budget/fixed/{tag}", r, steps)

    policy = make_budget_policy(ceiling=60.0)
    r_ad = run_variant(ds, "cg", pathwise=True, warm=True, budget=0.0,
                       record_history=RECORD_HISTORY, budget_policy=policy,
                       **kw)
    adaptive = _arm_row("adaptive_budget/adaptive", r_ad, steps)
    adaptive["alloc_per_step"] = [
        float(a) for a in r_ad["budget_alloc_per_step"]
    ]

    # Steady-state retraces: a second adaptive fit with a different seed
    # AND different (traced) policy coefficients must hit the same
    # executables — the controller state is data, not program structure.
    compiles0 = _scan_cache_size()
    policy2 = make_budget_policy(ceiling=50.0, margin=1.2, safety=1.3)
    run_variant(ds, "cg", pathwise=True, warm=True, budget=0.0,
                record_history=RECORD_HISTORY, budget_policy=policy2,
                seed=1, **kw)
    retraces = _scan_cache_size() - compiles0 if compiles0 >= 0 else None

    for row in list(arms.values()) + [adaptive]:
        csv_line(
            row["name"], row["us_per_step"],
            f"cum_epochs={row['cum_epochs']:.1f};"
            f"final_res_z={row['final_res_z']:.4f};"
            f"llh={row['test_llh'] if row['test_llh'] is not None else float('nan'):.3f}",
        )

    # Quality-matched comparator: fixed arms whose final res_z is
    # equal-or-better than the adaptive arm's (up to the tolerance — two
    # arms both below tau solved the same problem).
    bar = max(TOLERANCE, adaptive["final_res_z"])
    matched = {t: a for t, a in arms.items() if a["final_res_z"] <= bar}
    assert matched, (
        f"no fixed arm reached final res_z <= {bar:.4f} — the to-tolerance "
        f"arm should always match; arms: "
        f"{ {t: a['final_res_z'] for t, a in arms.items()} }"
    )
    best_tag = min(matched, key=lambda t: matched[t]["cum_epochs"])
    best = matched[best_tag]
    ratio = best["cum_epochs"] / max(adaptive["cum_epochs"], 1e-9)

    print(f"# adaptive-budget: {steps} steps @ n={max_n}: adaptive "
          f"{adaptive['cum_epochs']:.1f} epochs (final res_z "
          f"{adaptive['final_res_z']:.4f}) vs best matched fixed "
          f"[{best_tag}] {best['cum_epochs']:.1f} ({ratio:.2f}x); "
          f"unmatched: {sorted(set(arms) - set(matched))}; "
          f"steady-state retraces: {retraces}")

    assert adaptive["final_res_z"] <= TOLERANCE, (
        f"adaptive arm did not converge: final res_z "
        f"{adaptive['final_res_z']:.4f} > tolerance {TOLERANCE}"
    )
    assert ratio >= MIN_EPOCH_RATIO, (
        f"adaptive spent {adaptive['cum_epochs']:.1f} cumulative epochs vs "
        f"{best['cum_epochs']:.1f} for the best quality-matched fixed "
        f"budget [{best_tag}] — ratio {ratio:.2f}x < {MIN_EPOCH_RATIO}x"
    )
    assert retraces in (None, 0), (
        f"{retraces} outer_scan retraces on the second adaptive fit — "
        f"policy state must be traced data, not program structure"
    )

    report = {
        "n": max_n, "steps": steps, "probes": probes,
        "tolerance": TOLERANCE, "record_history": RECORD_HISTORY,
        "solver": "cg", "estimator": "pathwise", "warm": True,
        "epoch_ratio_best_fixed_over_adaptive": ratio,
        "best_fixed": best_tag,
        "steady_state_retraces": retraces,
        "adaptive": adaptive,
        "fixed": list(arms.values()),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_adaptive_budget.json"), "w") as f:
        json.dump(report, f, indent=2, default=str)
    print("[adaptive-budget] OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid; asserts still apply")
    ap.add_argument("--out-dir", default="artifacts/bench")
    args = ap.parse_args()
    main(small=not args.full, out_dir=args.out_dir, smoke=args.smoke)
