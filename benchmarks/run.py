"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]
                                            [--out-dir artifacts/bench]

Prints ``name,us_per_call,derived`` CSV lines (plus the roofline table from
any dry-run artifacts present) and writes one machine-readable
``BENCH_<module>.json`` per module to ``--out-dir``: wall-clock, the parsed
CSV rows, and — merged in, when a module writes its own richer BENCH file
(e.g. batched_sweep's lanes/retrace counts) — that module's extra fields.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import time
import traceback

MODULES = [
    "table1",
    "fig3_initial_distance",
    "fig4_probe_scaling",
    "fig5_trajectories",
    "fig6_warmstart_distance",
    "fig9_budget",
    "kernel_microbench",
    "batched_sweep",
    "sharded_sweep",
    "serve_cluster",
    "online_bo",
    "obs_overhead",
    "adaptive_budget",
]


class _Tee(io.TextIOBase):
    """Write-through stdout capture (benchmarks stay live on the console)."""

    def __init__(self, stream):
        self.stream = stream
        self.lines: list[str] = []
        self._buf = ""

    def write(self, s):
        self.stream.write(s)
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            self.lines.append(line)
        return len(s)

    def flush(self):
        self.stream.flush()


def parse_csv_rows(lines: list[str]) -> list[dict]:
    """The ``name,us_per_call,derived`` line protocol of benchmarks.common."""
    rows = []
    for line in lines:
        parts = line.split(",", 2)
        if len(parts) != 3 or line.startswith("#"):
            continue
        try:
            value = float(parts[1])
        except ValueError:
            continue
        rows.append({"name": parts[0], "us_per_call": value,
                     "derived": parts[2]})
    return rows


def write_bench_json(out_dir: str, module: str, wall_s: float,
                     rows: list[dict], failed: bool):
    """BENCH_<module>.json; preserves any fields the module wrote itself.

    Each successful report is also appended (flattened) to the per-module
    rolling history under ``<out_dir>/history/`` — the baseline feed of
    ``tools/bench_history.py``.
    """
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{module}.json")
    report = {}
    if not failed and os.path.exists(path):
        # Merge fields the module wrote itself during THIS run (e.g.
        # batched_sweep's lanes/retrace counts). A failed run must not
        # inherit stale numbers from an earlier success.
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            report = {}
    report.update({
        "module": module,
        "wall_s": wall_s,
        "failed": failed,
        "rows": rows,
    })
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    try:
        from benchmarks import history

        history.append_history(out_dir, module, report)
    except Exception as e:  # history is advisory; never fail the bench run
        print(f"# history append failed for {module}: {e}", file=sys.stderr)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (hours); default is CPU-quick")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out-dir", default="artifacts/bench")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        tee = _Tee(sys.stdout)
        failed = False
        try:
            import inspect

            kwargs = {"small": not args.full}
            if "out_dir" in inspect.signature(mod.main).parameters:
                kwargs["out_dir"] = args.out_dir
            with contextlib.redirect_stdout(tee):
                mod.main(**kwargs)
        except Exception:
            failed = True
            failures.append(name)
            traceback.print_exc()
        dt = time.time() - t0
        write_bench_json(args.out_dir, name, dt, parse_csv_rows(tee.lines),
                         failed)
        print(f"# {name} took {dt:.1f}s", flush=True)

    # roofline table (reads artifacts/dryrun if present)
    try:
        from benchmarks import roofline

        print("# --- roofline (from dry-run artifacts) ---")
        t0 = time.time()
        tee = _Tee(sys.stdout)
        with contextlib.redirect_stdout(tee):
            roofline.main(["--csv"])
        write_bench_json(args.out_dir, "roofline", time.time() - t0,
                         parse_csv_rows(tee.lines), failed=False)
    except Exception:
        failures.append("roofline")
        traceback.print_exc()

    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
