"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,...]

Prints ``name,us_per_call,derived`` CSV lines (plus the roofline table from
any dry-run artifacts present).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    "table1",
    "fig3_initial_distance",
    "fig4_probe_scaling",
    "fig5_trajectories",
    "fig6_warmstart_distance",
    "fig9_budget",
    "kernel_microbench",
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (hours); default is CPU-quick")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None
    failures = []
    for name in MODULES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod.main(small=not args.full)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} took {time.time()-t0:.1f}s", flush=True)

    # roofline table (reads artifacts/dryrun if present)
    try:
        from benchmarks import roofline

        print("# --- roofline (from dry-run artifacts) ---")
        roofline.main(["--csv"])
    except Exception:
        failures.append("roofline")
        traceback.print_exc()

    if failures:
        print(f"# FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
