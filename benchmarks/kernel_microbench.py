"""Kernel MVM backend microbenchmark + Pallas kernel working-set report.

Runs per registered kernel (RBF + Matérn family). Wall-clock on CPU covers
the jnp backends (dense vs streamed). The Pallas kernel runs in interpret
mode here (correctness only — interpret wall time is meaningless), so its
entry reports the STRUCTURAL roofline quantities of the BlockSpec tiling
for TPU v5e instead: VMEM working set, per-tile arithmetic intensity, and
the bound it implies. The tiling is shared across kernels; only the
per-tile profile flop count differs.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import csv_line
from repro.gp.hyperparams import HyperParams
from repro.gp.kernels_math import h_mvm_dense, h_mvm_streamed
from repro.kernels.registry import available_kernels
from repro.launch.mesh import HBM_BW, PEAK_BF16_FLOPS

# Per-tile profile evaluation cost (VPU flops per kernel entry), on top of
# the shared distance-tile GEMM: transcendental + polynomial terms.
PROFILE_FLOPS = {"rbf": 8, "matern12": 10, "matern32": 10, "matern52": 12}


def _time(f, *args, reps=3):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def main(small: bool = True):
    n, d, s = (2048, 8, 16) if small else (16384, 8, 65)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    v = jax.random.normal(jax.random.fold_in(key, 1), (n, s))

    for kind in available_kernels():
        p = HyperParams.create(d, noise=0.3, kernel=kind)
        prof_flops = PROFILE_FLOPS.get(kind, 10)

        dense = jax.jit(lambda x, v, p=p: h_mvm_dense(x, v, p))
        streamed = jax.jit(lambda x, v, p=p: h_mvm_streamed(x, v, p,
                                                            block_rows=512))
        t_dense = _time(dense, x, v)
        t_streamed = _time(streamed, x, v)
        flops = 2 * n * n * (d + s) + prof_flops * n * n
        csv_line(f"kernel/{kind}/dense", t_dense * 1e6,
                 f"gflops={flops/t_dense/1e9:.1f}")
        csv_line(f"kernel/{kind}/streamed", t_streamed * 1e6,
                 f"gflops={flops/t_streamed/1e9:.1f};mem=O(block*n)")

        # Pallas kernel structural report (TPU target; interpret-validated)
        bm = bn = 256
        s_pad = 128
        vmem = (bm * d + bn * d + bn * s_pad + bm * bn + bm * s_pad) * 4
        tile_flops = (2 * bm * bn * d + prof_flops * bm * bn
                      + 2 * bm * bn * s_pad)
        tile_bytes = (bm * d + bn * d + bn * s_pad + bm * s_pad) * 4
        intensity = tile_flops / tile_bytes
        ridge = PEAK_BF16_FLOPS / HBM_BW
        bound = "compute" if intensity > ridge else "memory"
        csv_line(
            f"kernel/{kind}/pallas_mvm_structural", 0.0,
            f"vmem_tile_bytes={vmem};intensity={intensity:.1f}flops/B;"
            f"v5e_ridge={ridge:.0f};bound={bound};"
            f"tile={bm}x{bn}xd{d}xs{s_pad}",
        )


if __name__ == "__main__":
    main()
