"""Fig. 6: initial RKHS distance to the current solution, cold (zero init)
vs warm (previous solution), along the MLL trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_dataset, csv_line
from repro.core import (
    PATHWISE,
    OuterConfig,
    build_system_targets,
    init_outer_state,
    outer_step,
)
from repro.gp.kernels_math import regularised_kernel_matrix
from repro.solvers import SolverConfig


def main(small: bool = True):
    ds = bench_dataset("pol", max_n=512 if small else 2000)
    x, y = ds.x_train, ds.y_train
    cfg = OuterConfig(
        estimator=PATHWISE, warm_start=True, num_probes=16,
        num_rff_pairs=400,
        solver=SolverConfig(name="cg", tolerance=0.01, max_epochs=300,
                            precond_rank=10),
        num_steps=1, bm=256, bn=256,
    )
    st = init_outer_state(jax.random.PRNGKey(0), cfg, x)
    steps = 10 if small else 30
    for t in range(steps):
        params = st.params
        h = regularised_kernel_matrix(x, params)
        targets = build_system_targets(st.probes, x, y, params)
        u_star = jnp.linalg.solve(h, targets)
        cold = jnp.mean(jnp.sum(u_star * (h @ u_star), axis=0))
        diff = u_star - st.carry_v
        warm = jnp.mean(jnp.sum(diff * (h @ diff), axis=0))
        csv_line(
            f"fig6/step{t}", 0.0,
            f"rms_dist_cold={float(jnp.sqrt(cold)):.3f};"
            f"rms_dist_warm={float(jnp.sqrt(warm)):.3f};"
            f"ratio={float(jnp.sqrt(warm/cold)):.3f}",
        )
        st, _ = outer_step(st, x, y, cfg)


if __name__ == "__main__":
    main()
