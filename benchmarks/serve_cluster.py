"""Cluster serving: q/s and latency across replica counts and overload
policies.

Three measurements on a SMOKE-sized fitted GP artifact:

  * **replica scaling** — closed-loop clients drive 1 vs 2 spawned replica
    processes (shared versioned artifact store) over HTTP; reports q/s and
    p50/p99 per replica count (2 processes sidestep the single-process
    GIL, so q/s should scale);
  * **shed vs no-shed overload** — the same traffic at ~2x a replica's
    capacity (8 closed-loop clients against one in-process server) with
    admission control OFF (everything queues) vs rate-based shedding ON
    (capped at half the measured no-shed throughput, i.e. 2x overload);
    asserts the ADMITTED requests get faster (p50 ordering) and their p99
    stays bounded — the point of load shedding is that the requests you do
    accept stay fast;
  * **stats format** — the `/stats` payload (EngineStats.as_dict + admission
    counters) is embedded in the JSON report, exercising the one shared
    stats wire format.

Emits ``BENCH_serve_cluster.json`` (merged by ``benchmarks/run.py``) and
the ``name,us_per_call,derived`` CSV lines the runner parses.

Run: PYTHONPATH=src python benchmarks/serve_cluster.py [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import jax
import numpy as np

from repro.core import OuterConfig, fit
from repro.data.synthetic import load_dataset
from repro.serve import BucketedEngine, export_servable
from repro.serve.cluster import (
    AdmissionController,
    ReplicaSupervisor,
    ServeFrontend,
    publish_servable,
    start_http_server,
)
from repro.serve.cluster.replica import _http_json
from repro.solvers import SolverConfig


def _drive(endpoints, payload, requests, clients):
    """Closed-loop client threads, round-robin over endpoints.

    Clients are well-behaved: a 429 is honoured with a (capped)
    ``retry_after_s`` backoff before the next request, as a production
    client would — hammering instant retries would only measure connection
    churn, not serving behaviour.

    Returns (wall_s, admitted_latencies_ms, status_counts).
    """
    lat_ms, statuses = [], []
    lock = threading.Lock()
    idx = {"i": 0}

    def worker(tid):
        for r in range(requests // clients):
            with lock:
                ep = endpoints[idx["i"] % len(endpoints)]
                idx["i"] += 1
            t0 = time.perf_counter()
            try:
                status, body = _http_json(ep + "/predict", payload,
                                          timeout=60)
            except OSError:
                status, body = -1, {}
            dt = (time.perf_counter() - t0) * 1e3
            with lock:
                statuses.append(status)
                if status == 200:
                    lat_ms.append(dt)
            if status == 429:
                time.sleep(min(0.2, float(body.get("retry_after_s", 0.05))))

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    counts = {}
    for s in statuses:
        counts[str(s)] = counts.get(str(s), 0) + 1
    return wall, lat_ms, counts


def _pcts(lat_ms):
    if not lat_ms:
        return {"p50_ms": float("nan"), "p99_ms": float("nan")}
    return {"p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99))}


def main(small: bool = True, out_dir: str = "artifacts/bench"):
    max_n, steps, requests = (512, 2, 60) if small else (2000, 5, 400)
    ds = load_dataset("pol", max_n=max_n)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=16,
        num_rff_pairs=128,
        solver=SolverConfig(name="cg", max_epochs=100, precond_rank=0),
        num_steps=steps, bm=256, bn=256,
    )
    res = fit(ds.x_train, ds.y_train, cfg, key=jax.random.PRNGKey(0))
    model = export_servable(res.state, ds.x_train)
    width = 16
    payload = {"x": np.asarray(ds.x_test[:width]).tolist()}
    report = {"small": small, "requests": requests, "width": width}

    # -- 1 vs 2 replica processes over one artifact store -------------------
    store = tempfile.mkdtemp(prefix="gp-bench-store-")
    publish_servable(store, model)
    report["replicas"] = {}
    for nrep in (1, 2):
        sup = ReplicaSupervisor(store, num_replicas=nrep, buckets=(16, 64),
                                bm=256, bn=256, poll_interval_s=5.0)
        try:
            endpoints = sup.start()
            _drive(endpoints, payload, requests=8, clients=2)  # warm the path
            wall, lat, counts = _drive(endpoints, payload, requests, 4)
            qps = len(lat) * width / wall
            row = {"qps": qps, "wall_s": wall, "status": counts, **_pcts(lat)}
            stats = {}
            for ep in endpoints:
                _, stats = _http_json(ep + "/stats")
            row["stats_sample"] = stats  # the shared stats wire format
            report["replicas"][str(nrep)] = row
            print(f"serve_cluster_{nrep}rep,"
                  f"{wall / max(1, len(lat)) * 1e6:.1f},"
                  f"qps={qps:.1f};p50={row['p50_ms']:.1f}ms;"
                  f"p99={row['p99_ms']:.1f}ms")
        finally:
            sup.stop()

    # -- shed vs no-shed at ~2x capacity (in-process, deterministic) --------
    # The no-shed control measures this machine's closed-loop throughput at
    # 8 clients; the shed run then rate-caps admission at HALF that, i.e.
    # the offered load is ~2x what admission lets through, so sheds are
    # guaranteed and the admitted requests face far less contention.
    report["overload"] = {}
    shed_rate = None
    for tag in ("noshed", "shed"):
        if tag == "noshed":
            admission = AdmissionController(buckets=(16, 64),
                                            max_inflight=10_000)
        else:
            # burst=1: the flood lasts ~a second, so a rate-sized burst
            # would admit the whole run before the cap ever bites.
            admission = AdmissionController(
                buckets=(16, 64), max_inflight=10_000,
                rate_qps=shed_rate, burst=1.0,
            )
        engine = BucketedEngine(model, buckets=(16, 64), bm=256, bn=256)
        engine.warmup()
        frontend = ServeFrontend(engine, admission)
        httpd, _ = start_http_server(frontend)
        try:
            ep = f"http://127.0.0.1:{httpd.port}"
            _drive([ep], payload, requests=8, clients=2)  # warm the path
            wall, lat, counts = _drive([ep], payload, requests, clients=8)
            row = {"wall_s": wall, "admitted": len(lat), "status": counts,
                   "admission": admission.as_dict(),
                   "engine": engine.stats_dict(), **_pcts(lat)}
            report["overload"][tag] = row
            if tag == "noshed":
                # warm-drive requests are admitted too; rate on the flood
                shed_rate = max(1.0, len(lat) / wall / 2.0)
            print(f"serve_cluster_overload_{tag},"
                  f"{wall / max(1, len(lat)) * 1e6:.1f},"
                  f"admitted={len(lat)};shed={row['admission']['shed']};"
                  f"p50={row['p50_ms']:.1f}ms;p99={row['p99_ms']:.1f}ms")
        finally:
            httpd.shutdown()

    shed, noshed = report["overload"]["shed"], report["overload"]["noshed"]
    assert shed["admitted"] > 0, "shedding admitted nothing"
    assert shed["admission"]["shed"] > 0, \
        "2x overload never tripped the admission control"
    # Admitted requests must be FASTER under shedding (less contention) and
    # their tail must stay bounded — the p50 ordering is the robust signal
    # (the p99 of a few dozen admitted samples is noisy, so it gets slack).
    assert shed["p50_ms"] < noshed["p50_ms"], (
        f"shedding did not speed up admitted requests: "
        f"shed p50 {shed['p50_ms']:.1f}ms vs no-shed {noshed['p50_ms']:.1f}ms"
    )
    assert shed["p99_ms"] <= 1.5 * noshed["p99_ms"], (
        f"shedding did not bound the admitted p99: "
        f"shed {shed['p99_ms']:.1f}ms vs no-shed {noshed['p99_ms']:.1f}ms"
    )
    print(f"# overload: shed p99 {shed['p99_ms']:.1f}ms <= "
          f"no-shed p99 {noshed['p99_ms']:.1f}ms "
          f"({shed['admission']['shed']} shed)")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_serve_cluster.json"), "w") as f:
        json.dump(report, f, indent=2, default=str)
    print("[serve-cluster] OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out-dir", default="artifacts/bench")
    args = ap.parse_args()
    main(small=not args.full, out_dir=args.out_dir)
