"""One-program batched sweep vs per-process sweep: wall-clock + retraces.

Runs the SAME kernel x seed grid (8 lanes: 2 kernels x 4 seeds) twice
through ``repro.launch.batch`` — once batched (one process, one executable
per kernel group, seeds as vmap lanes) and once ``--isolate`` (the legacy
one-subprocess-per-cell sweep) — each timed as a fresh top-level process so
interpreter + jax startup is charged where it is actually paid. Asserts the
batched path is >= 2x faster and compiled exactly one executable per static
group, and writes a machine-readable ``BENCH_batched_sweep.json``.

    PYTHONPATH=src python benchmarks/batched_sweep.py [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

KERNELS = "matern32,rbf"
SEEDS = 4  # x 2 kernels = 8 lanes
MIN_SPEEDUP = 2.0


def _run_sweep(out_dir: str, isolate: bool, max_n: int, steps: int) -> float:
    cmd = [
        sys.executable, "-m", "repro.launch.batch",
        "--out", out_dir, "--dataset", "pol", "--max-n", str(max_n),
        "--kernels", KERNELS, "--seeds", str(SEEDS), "--steps", str(steps),
        "--smoke",
    ]
    if isolate:
        cmd.append("--isolate")
    # Prepend the repo's src dir, keep the inherited PYTHONPATH (same
    # pattern as launch/batch.py's isolate workers).
    src = os.path.join(REPO, "src")
    inherited = os.environ.get("PYTHONPATH")
    pypath = src + (os.pathsep + inherited if inherited else "")
    t0 = time.perf_counter()
    r = subprocess.run(
        cmd, capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": pypath}, timeout=3600,
    )
    dt = time.perf_counter() - t0
    if r.returncode != 0:
        raise RuntimeError(
            f"sweep ({'isolated' if isolate else 'batched'}) failed:\n"
            f"{(r.stderr or r.stdout)[-3000:]}"
        )
    return dt


def csv_line(name: str, value: float, derived: str):
    # Same line protocol as benchmarks.common (not imported so this file
    # also runs as a bare script, like serve_throughput.py).
    print(f"{name},{value:.1f},{derived}")


def main(small: bool = True, out_dir: str = "artifacts/bench"):
    max_n, steps = (256, 3) if small else (512, 5)
    with tempfile.TemporaryDirectory() as d_batch, \
            tempfile.TemporaryDirectory() as d_iso:
        t_batched = _run_sweep(d_batch, isolate=False, max_n=max_n, steps=steps)
        t_isolated = _run_sweep(d_iso, isolate=True, max_n=max_n, steps=steps)
        with open(os.path.join(d_batch, "_sweep_status.json")) as f:
            status = json.load(f)
        n_cells = len([
            p for p in os.listdir(d_batch) if not p.startswith("_")
        ])

    lanes = status["cells"]
    speedup = t_isolated / t_batched
    report = {
        "bench": "batched_sweep",
        "grid": {"kernels": KERNELS.split(","), "seeds": SEEDS,
                 "max_n": max_n, "steps": steps},
        "lanes": lanes,
        "groups": status["groups"],
        "num_compiles": status["num_compiles"],
        "wall_batched_s": t_batched,
        "wall_isolated_s": t_isolated,
        "speedup": speedup,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_batched_sweep.json"), "w") as f:
        json.dump(report, f, indent=2)

    csv_line("batched_sweep_one_program", t_batched * 1e6,
             f"lanes={lanes} groups={status['groups']} "
             f"compiles={status['num_compiles']}")
    csv_line("batched_sweep_per_process", t_isolated * 1e6,
             f"cells={n_cells}")
    csv_line("batched_sweep_speedup", speedup, "x (isolated / batched)")

    assert lanes == 2 * SEEDS, f"expected {2*SEEDS} cells, got {lanes}"
    assert status["num_compiles"] == status["groups"] == 2, status
    assert speedup >= MIN_SPEEDUP, (
        f"batched sweep only {speedup:.2f}x faster "
        f"(need >= {MIN_SPEEDUP}x): batched={t_batched:.1f}s "
        f"isolated={t_isolated:.1f}s"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="artifacts/bench")
    args = ap.parse_args()
    main(small=not args.full, out_dir=args.out)
