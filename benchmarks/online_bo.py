"""Online sequential inference: a BO loop on the serving engine, warm vs cold.

The sequential regime is where the paper's warm-start machinery compounds:
every acquisition round appends ONE observation, so re-solving the linear
systems from scratch (the cold baseline) repays nearly the full solve cost
for a rank-one change, while the warm path reuses the carry — block
refresh on the appended row, damped old-row correction, auto-escalation
only when the corrected residual stays above threshold (see
``repro.online.bo.run_bo`` and Dong et al., 2025).

One A/B on a Gaussian-bumps objective, both arms running the IDENTICAL
loop (same engine, same candidate draws, same tolerance, same geometric
capacity reservation — so shapes, compiles, and acquisition behaviour
match) differing only in the refresh policy:

  * **warm** — ``refine(mode="auto", correction="damped")`` per round;
  * **cold** — ``refine(mode="solve", warm=False)`` per round (full
    re-solve from zero initialisation).

Asserted (the tentpole's acceptance bars):

  * warm cumulative solver epochs <= 0.5 x cold;
  * ZERO engine retraces after bucket warmup, both arms;
  * the warm arm compiles O(log N) solver executables for its N appends
    (with up-front reservation: exactly one full + one block executable).

Emits ``BENCH_online_bo.json`` (merged by ``benchmarks/run.py``) and the
``name,us_per_call,derived`` CSV lines the runner parses. Preconditioning
is disabled in both arms: at benchmark sizes a rank-100 preconditioner is
essentially exact, which would hide the cold arm's true per-round cost.

Run: PYTHONPATH=src python benchmarks/online_bo.py [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.core import OuterConfig, fit
from repro.gp.hyperparams import HyperParams
from repro.online import BOConfig, make_gaussian_bumps, run_bo
from repro.solvers import SolverConfig

from common import csv_line


def _fit_initial(objective, key, n0, d, cfg):
    x0 = jax.random.uniform(
        jax.random.fold_in(key, 0), (n0, d), minval=-1.0, maxval=1.0,
        dtype=jax.numpy.float32,
    )
    y0 = objective(x0)
    params = HyperParams.create(d, lengthscale=0.3, signal=1.0, noise=0.1)
    res = fit(x0, y0, cfg, key=jax.random.fold_in(key, 1),
              init_params=params)
    return x0, y0, res.state


def main(small: bool = True, out_dir: str = "artifacts/bench",
         smoke: bool = False):
    if smoke:  # CI tier: same loop and asserts, minutes -> seconds
        rounds, n0, num_candidates = 60, 128, 256
    else:
        rounds = 200 if small else 400
        n0 = 256 if small else 512
        num_candidates = 512 if small else 2048
    d = 2
    key = jax.random.PRNGKey(0)
    objective, f_opt = make_gaussian_bumps(jax.random.fold_in(key, 7), d=d)

    scfg = SolverConfig(name="cg", tolerance=1e-2, precond_rank=0)
    cfg = OuterConfig(
        estimator="pathwise", num_probes=8, num_rff_pairs=128,
        solver=scfg, num_steps=5, bm=256, bn=256,
    )
    x0, y0, state = _fit_initial(objective, key, n0, d, cfg)

    arms = {
        "warm": BOConfig(rounds=rounds, num_candidates=num_candidates,
                         refresh_mode="auto", correction="damped"),
        "cold": BOConfig(rounds=rounds, num_candidates=num_candidates,
                         warm=False),
    }
    results = {}
    for name, bo in arms.items():
        t0 = time.perf_counter()
        out = run_bo(objective, x0, y0, state, cfg, bo=bo,
                     bounds=(-1.0, 1.0), f_opt=f_opt)
        wall = time.perf_counter() - t0
        results[name] = out
        csv_line(
            f"online_bo_{name}_round", wall / rounds * 1e6,
            f"epochs={out.cum_epochs:.1f} escalations={out.escalations} "
            f"corrections={out.corrections} regret={out.regret:.4f} "
            f"retraces={out.engine_retraces}",
        )

    warm, cold = results["warm"], results["cold"]
    ratio = warm.cum_epochs / max(cold.cum_epochs, 1e-9)
    print(f"# online-bo: {rounds} rounds x {num_candidates} candidates, "
          f"n0={n0}: warm {warm.cum_epochs:.1f} epochs vs cold "
          f"{cold.cum_epochs:.1f} ({ratio:.3f}x), "
          f"warm {warm.rounds_per_sec:.1f} rounds/s, "
          f"escalations={warm.escalations}, regret={warm.regret:.4f}")

    # Acceptance bars — a regression in the warm path fails the benchmark
    # loudly rather than drifting.
    assert ratio <= 0.5, (
        f"warm cumulative epochs {warm.cum_epochs:.1f} > 0.5x cold "
        f"{cold.cum_epochs:.1f} (ratio {ratio:.3f})"
    )
    for name, out in results.items():
        assert out.engine_retraces in (None, 0), (
            f"{name}: {out.engine_retraces} engine retraces after warmup"
        )
    if warm.solve_compiles is not None:
        # One full-system + one block executable: capacity is reserved up
        # front, so N appends never change a traced shape.
        assert warm.solve_compiles <= 4, (
            f"warm arm compiled {warm.solve_compiles} solver executables; "
            f"expected O(1) with reserved capacity"
        )

    def arm_report(out):
        return {
            "cum_epochs": out.cum_epochs,
            "escalations": out.escalations,
            "corrections": out.corrections,
            "rounds_per_sec": out.rounds_per_sec,
            "engine_retraces": out.engine_retraces,
            "solve_compiles": out.solve_compiles,
            "best_y": out.best_y,
            "regret": out.regret,
            "refresh_stats": out.refresh_stats,
        }

    report = {
        "rounds": rounds, "num_candidates": num_candidates, "n0": n0,
        "d": d, "f_opt": f_opt, "tolerance": scfg.tolerance,
        "epoch_ratio_warm_over_cold": ratio,
        "warm": arm_report(warm), "cold": arm_report(cold),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "BENCH_online_bo.json"), "w") as f:
        json.dump(report, f, indent=2, default=str)
    print("[online-bo] OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized loop (60 rounds); asserts still apply")
    ap.add_argument("--out-dir", default="artifacts/bench")
    args = ap.parse_args()
    main(small=not args.full, out_dir=args.out_dir, smoke=args.smoke)
