"""Table 1 (and Tables 2-6): solver x estimator x warm-start grid,
solving to tolerance. Reports test LLH/RMSE, total time, solver epochs,
and speed-ups relative to the standard/cold baseline per solver.

CPU-feasible n; the paper's structural claims are scale-free:
  * pathwise+warm is the fastest AP/SGD variant (up to 72x in the paper),
  * CG gains less from warm starts (~2x) than AP/SGD,
  * predictive metrics are indistinguishable across variants.
"""
from __future__ import annotations

import json

from benchmarks.common import bench_dataset, csv_line, run_variant

VARIANTS = [(False, False), (True, False), (False, True), (True, True)]


def main(small: bool = True, datasets=("pol",), out_json=None):
    max_n = 800 if small else 4000
    steps = 20 if small else 60
    rows = []
    for ds_name in datasets:
        ds = bench_dataset(ds_name, max_n=max_n)
        for solver in ("cg", "ap", "sgd"):
            base_epochs = None
            for pathwise, warm in VARIANTS:
                r = run_variant(ds, solver, pathwise, warm, steps=steps)
                r["dataset"] = ds_name
                if (pathwise, warm) == (False, False):
                    base_epochs = r["total_epochs"]
                    base_time = r["total_time_s"]
                r["speedup_epochs"] = base_epochs / max(r["total_epochs"], 1e-9)
                r["speedup_time"] = base_time / max(r["total_time_s"], 1e-9)
                rows.append(r)
                name = (f"table1/{ds_name}/{solver}"
                        f"/{'path' if pathwise else 'std'}"
                        f"{'+warm' if warm else ''}")
                csv_line(
                    name,
                    r["total_time_s"] * 1e6 / steps,
                    f"epochs={r['total_epochs']:.1f};"
                    f"speedup_epochs={r['speedup_epochs']:.2f}x;"
                    f"llh={r.get('test_llh', float('nan')):.3f};"
                    f"rmse={r.get('test_rmse', float('nan')):.4f}",
                )
    if out_json:
        slim = [{k: v for k, v in r.items()
                 if k not in ("hypers", "res_z_per_step", "iters_per_step")}
                for r in rows]
        with open(out_json, "w") as f:
            json.dump(slim, f, indent=2)
    return rows


if __name__ == "__main__":
    main()
