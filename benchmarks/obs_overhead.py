"""Observability overhead A/B: telemetry must be free when off, cheap when on.

Three arms over the same `fit` (cg, pathwise, warm-started):

  * **off**       — `record_history=0`, no event log, NULL metrics registry:
    the plain training path;
  * **off+log**   — identical solver config but with a JSONL event log
    attached and the default metrics registry live. The jitted program is
    untouched (host-side aggregation only), so the hyperparameter trajectory
    must be BIT-identical to the off arm and the `outer_scan` jit cache must
    not grow;
  * **on**        — `record_history=H` rings plus the event log. This is a
    different static config (the ring is loop-carried state), so it compiles
    once; after warmup repeated fits must add ZERO new executables, and the
    steady-state wall cost must stay within ``OVERHEAD_FRAC`` of the off arm.

Prints ``name,us_per_call,derived`` CSV rows (run.py protocol) and raises
SystemExit on any violated bound.

Run: PYTHONPATH=src python benchmarks/obs_overhead.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.core import OuterConfig, fit
from repro.data.synthetic import load_dataset
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.solvers import SolverConfig

# The acceptance bound: recording rings + emitting solve_step events may
# cost at most this fraction over the plain path (median of repeats).
OVERHEAD_FRAC = 0.05
# Host-timer noise floor: on sub-second fits a single scheduler hiccup is
# worth more than 5%, so the bound is enforced against max(5%, NOISE_S).
NOISE_S = 0.05


def _scan_cache_size():
    """Executable count of the outer_scan jit (None = no introspection)."""
    from repro.core.outer import outer_scan

    try:
        return int(outer_scan._cache_size())
    except AttributeError:
        return None


def _timed_arms(ds, arms, repeats):
    """Time ``arms`` ({name: (cfg, event_log)}) with INTERLEAVED repeats.

    Arms alternate within each round rather than running back to back:
    sequential blocks pick up monotone host drift (frequency scaling, page
    cache warmth) that dwarfs the few-percent effect being measured.
    Returns ({name: median_wall_s}, {name: last FitResult}).
    """
    results = {}
    for name, (cfg, log) in arms.items():  # compile warmup, untimed
        results[name] = fit(ds.x_train, ds.y_train, cfg,
                            key=jax.random.PRNGKey(0), event_log=log)
    walls = {name: [] for name in arms}
    for _ in range(repeats):
        for name, (cfg, log) in arms.items():
            t0 = time.perf_counter()
            results[name] = fit(ds.x_train, ds.y_train, cfg,
                                key=jax.random.PRNGKey(0), event_log=log)
            walls[name].append(time.perf_counter() - t0)
    return {n: float(np.median(w)) for n, w in walls.items()}, results


def main(small: bool = True, out_dir: str = "artifacts/bench"):
    max_n, steps, repeats = (500, 4, 3) if small else (2000, 10, 5)
    ds = load_dataset("pol", max_n=max_n)

    def make_cfg(record_history):
        return OuterConfig(
            estimator="pathwise", warm_start=True, num_probes=16,
            num_rff_pairs=128,
            solver=SolverConfig(name="cg", max_epochs=30, precond_rank=0,
                                record_history=record_history),
            num_steps=steps, bm=256, bn=256,
        )

    log_dir = tempfile.mkdtemp(prefix="gp-obs-bench-")
    log_path = os.path.join(log_dir, "events.jsonl")
    log = obs_trace.EventLog(path=log_path)

    # Arm 1 is the plain path; arm 2 attaches the event log with recording
    # still off (the jitted program is untouched — jit cache must not grow
    # and the trajectory must be bit-identical); arm 3 records rings too.
    compiles0 = _scan_cache_size()
    arms = {
        "off": (make_cfg(0), None),
        "off_log": (make_cfg(0), log),
        "on": (make_cfg(32), log),
    }
    t, res = _timed_arms(ds, arms, repeats)
    t_off, t_log, t_on = t["off"], t["off_log"], t["on"]
    res_off, res_log, res_on = res["off"], res["off_log"], res["on"]
    compiles1 = _scan_cache_size()
    print(f"obs_off,{t_off * 1e6:.0f},fit wall (telemetry off)")
    print(f"obs_off_log,{t_log * 1e6:.0f},fit wall (event log, no rings)")
    print(f"obs_on,{t_on * 1e6:.0f},fit wall (rings + event log)")

    same = jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)),
        res_off.state.params, res_log.state.params))
    if not same:
        raise SystemExit("[obs-overhead] event log changed the trajectory "
                         "(params not bit-identical)")

    # Steady state after the warmup round must not retrace: the timed
    # repeats of all three arms (including every ring-recording fit) may
    # add zero executables beyond the two statics compiled during warmup.
    fit(ds.x_train, ds.y_train, make_cfg(32), key=jax.random.PRNGKey(0),
        event_log=log)
    compiles2 = _scan_cache_size()
    log.close()
    if compiles0 is not None and compiles2 != compiles1:
        raise SystemExit(f"[obs-overhead] recording retraced in steady "
                         f"state: {compiles1} -> {compiles2}")

    if "res_history" not in res_on.history:
        raise SystemExit("[obs-overhead] on arm recorded no res_history")

    budget = max(t_off * OVERHEAD_FRAC, NOISE_S)
    overhead = t_on - t_off
    frac = overhead / t_off if t_off > 0 else 0.0
    print(f"obs_overhead_frac,{frac * 1e6:.0f},"
          f"micro-fraction ({frac * 100:.2f}% of off-arm wall)")
    if overhead > budget:
        raise SystemExit(
            f"[obs-overhead] telemetry cost {overhead * 1e3:.1f}ms "
            f"({frac * 100:.1f}%) exceeds budget {budget * 1e3:.1f}ms")

    events = sum(1 for _ in open(log_path))
    # Each logged fit emits `steps` solve_step events + one fit_done. Logged
    # fits: off_log + on warmups, repeats x (off_log + on), the retrace probe.
    expected = (2 * (repeats + 1) + 1) * (steps + 1)
    if events != expected:
        raise SystemExit(f"[obs-overhead] expected {expected} events, "
                         f"logged {events}")
    print(f"[obs-overhead] off={t_off * 1e3:.0f}ms log={t_log * 1e3:.0f}ms "
          f"on={t_on * 1e3:.0f}ms ({frac * 100:+.2f}%), "
          f"{events} events, bit-identical off path, no retraces — OK")

    # -- serve hot path: instrumented engine vs NULL registry ----------------
    from repro.serve import BucketedEngine, export_servable

    model = export_servable(res_off.state, ds.x_train)
    width = min(16, ds.x_test.shape[0])
    xq = ds.x_test[:width]
    requests = 30 if small else 200
    eng_off = BucketedEngine(model, buckets=(width,), bm=256, bn=256,
                             registry=obs_metrics.NULL_REGISTRY)
    eng_on = BucketedEngine(model, buckets=(width,), bm=256, bn=256)
    eng_off.warmup()
    eng_on.warmup()
    p_off = eng_off.submit(xq)
    serve_log = os.path.join(log_dir, "serve.jsonl")
    obs_trace.configure(path=serve_log)
    p_on = eng_on.submit(xq)
    compiles_on = eng_on.num_compiles()
    if not np.array_equal(np.asarray(p_off.mean), np.asarray(p_on.mean)):
        raise SystemExit("[obs-overhead] instrumentation changed serve "
                         "predictions")

    serve_walls = {"off": [], "on": []}
    for _ in range(repeats):  # interleaved, same reasoning as the fit arms
        obs_trace.configure()  # off round: no event log active
        t0 = time.perf_counter()
        for _ in range(requests):
            jax.block_until_ready(eng_off.submit(xq).mean)
        serve_walls["off"].append(time.perf_counter() - t0)
        obs_trace.configure(path=serve_log)
        t0 = time.perf_counter()
        for _ in range(requests):
            jax.block_until_ready(eng_on.submit(xq).mean)
        serve_walls["on"].append(time.perf_counter() - t0)
    obs_trace.configure()
    s_off = float(np.median(serve_walls["off"]))
    s_on = float(np.median(serve_walls["on"]))
    print(f"serve_off,{s_off / requests * 1e6:.0f},per-request (NULL registry)")
    print(f"serve_on,{s_on / requests * 1e6:.0f},per-request (metrics + spans)")
    if (eng_on.num_compiles() is not None
            and eng_on.num_compiles() != compiles_on):
        raise SystemExit(f"[obs-overhead] instrumented engine retraced: "
                         f"{compiles_on} -> {eng_on.num_compiles()}")
    s_budget = max(s_off * OVERHEAD_FRAC, NOISE_S)
    if s_on - s_off > s_budget:
        raise SystemExit(
            f"[obs-overhead] serve instrumentation cost "
            f"{(s_on - s_off) * 1e3:.1f}ms over {requests} requests "
            f"({(s_on / s_off - 1) * 100:.1f}%) exceeds budget "
            f"{s_budget * 1e3:.1f}ms")
    spans = sum(1 for line in open(serve_log)
                if json.loads(line).get("span") == "engine.submit")
    if spans < requests * repeats:
        raise SystemExit(f"[obs-overhead] expected >= {requests * repeats} "
                         f"engine spans, logged {spans}")
    print(f"[obs-overhead] serve off={s_off / requests * 1e3:.2f}ms "
          f"on={s_on / requests * 1e3:.2f}ms per request "
          f"({(s_on / s_off - 1) * 100:+.2f}%), identical predictions, "
          f"no retraces — OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    a = ap.parse_args()
    main(small=a.quick)
