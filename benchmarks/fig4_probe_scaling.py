"""Fig. 4: probe-count scaling. Using 4x more probes costs ~10% more time
because kernel-matrix evaluations are shared across the batched systems.
Measures wall time and epochs for s in {8, 16, 32, 64}.
"""
from __future__ import annotations

from benchmarks.common import bench_dataset, csv_line, run_variant


def main(small: bool = True):
    ds = bench_dataset("pol", max_n=512 if small else 2000)
    steps = 8 if small else 25
    base = None
    for s in (8, 16, 32, 64):
        r = run_variant(ds, "cg", pathwise=True, warm=True, steps=steps,
                        probes=s, eval_at_end=False)
        if base is None:
            base = r["total_time_s"]
        csv_line(
            f"fig4/probes{s}",
            r["total_time_s"] * 1e6 / steps,
            f"epochs={r['total_epochs']:.1f};"
            f"time_vs_s8={r['total_time_s']/base:.2f}x",
        )


if __name__ == "__main__":
    main()
