"""Fig. 3: initial RKHS distance to the linear-system solution.

Measures, along a short MLL trajectory:
  * E||u||_H^2 for standard probes  -> tr(H^-1)      (eq. 14)
  * E||u||_H^2 for pathwise probes  -> n             (eq. 15)
  * top eigenvalue of H^-1 vs noise precision 1/sigma^2
  * AP iterations-to-tolerance under each estimator
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_dataset, csv_line
from repro.core import (
    PATHWISE,
    STANDARD,
    OuterConfig,
    init_outer_state,
    init_probes,
    outer_step,
    probe_targets,
)
from repro.gp.kernels_math import regularised_kernel_matrix
from repro.solvers import SolverConfig


def main(small: bool = True):
    ds = bench_dataset("pol", max_n=512 if small else 2000)
    x, y = ds.x_train, ds.y_train
    n, d = x.shape
    cfg = OuterConfig(
        estimator=PATHWISE, warm_start=True, num_probes=16,
        num_rff_pairs=400,
        solver=SolverConfig(name="cg", tolerance=0.01, max_epochs=300,
                            precond_rank=10),
        num_steps=1, bm=256, bn=256,
    )
    state = init_outer_state(jax.random.PRNGKey(0), cfg, x)
    steps = 8 if small else 20
    for t in range(steps):
        params = state.params
        h = regularised_kernel_matrix(x, params)
        h_inv = jnp.linalg.inv(h)
        tr = float(jnp.trace(h_inv))
        lam_max = float(jnp.linalg.eigvalsh(h_inv)[-1])
        noise_prec = float(1.0 / params.noise**2)

        dists = {}
        iters = {}
        for est in (STANDARD, PATHWISE):
            probes = init_probes(jax.random.PRNGKey(50 + t), est, n, d, 64, 400)
            b = probe_targets(probes, x, params)
            u = h_inv @ b
            dists[est] = float(jnp.mean(jnp.sum(u * (h @ u), axis=0)))
            from repro.solvers import HOperator, solve

            op = HOperator(x=x, params=params, backend="streamed",
                           bm=256, bn=256)
            bs = next(bb for bb in range(64, 9, -1) if n % bb == 0)
            scfg = SolverConfig(name="ap", tolerance=0.01, max_epochs=300,
                                block_size=bs)
            res = solve(op, b, None, scfg)
            iters[est] = int(res.iters)

        csv_line(
            f"fig3/step{t}",
            0.0,
            f"tr_Hinv={tr:.1f};n={n};dist_std={dists[STANDARD]:.1f};"
            f"dist_path={dists[PATHWISE]:.1f};lam_max={lam_max:.3f};"
            f"noise_prec={noise_prec:.2f};ap_iters_std={iters[STANDARD]};"
            f"ap_iters_path={iters[PATHWISE]}",
        )
        state, _ = outer_step(state, x, y, cfg)

    # Theory assertions (printed, consumed by EXPERIMENTS.md)
    ratio = dists[STANDARD] / tr
    csv_line("fig3/theory_check", 0.0,
             f"dist_std_over_trace={ratio:.3f};dist_path_over_n="
             f"{dists[PATHWISE]/n:.3f}")


if __name__ == "__main__":
    main()
