"""Benchmark history: append-only per-module JSONL under the bench dir.

Every ``benchmarks.run`` invocation appends one flattened snapshot of each
module's ``BENCH_<module>.json`` to ``<out_dir>/history/<module>.jsonl`` —
the raw material of the regression observatory (``tools/bench_history.py``).
A history line is ``{"ts": ..., "metrics": {dotted.key: number}}``: only
numeric scalars survive flattening, so every line is directly comparable
against any other regardless of which extra fields a module wrote.

Stdlib only, no repro imports — usable from CI without jax installed.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional

HISTORY_DIRNAME = "history"

# Flattening guards: benchmark reports are shallow; anything deeper is a
# mistake we refuse to mirror into history.
_MAX_DEPTH = 4

# Report keys that are bookkeeping, not metrics.
_SKIP_KEYS = {"module", "failed", "ts", "schema_version"}


def flatten_metrics(report: dict) -> Dict[str, float]:
    """Flatten a BENCH report into ``{dotted.key: number}``.

    Numeric scalars keep their (dotted) key path; the ``rows`` list —
    the ``name,us_per_call,derived`` CSV protocol — becomes
    ``<row_name>.us_per_call`` entries; booleans and other lists are
    skipped (histories hold comparable numbers only).
    """
    out: Dict[str, float] = {}

    def visit(prefix: str, value, depth: int) -> None:
        if depth > _MAX_DEPTH:
            return
        if isinstance(value, bool):
            return
        if isinstance(value, (int, float)):
            out[prefix] = float(value)
            return
        if isinstance(value, dict):
            for k, v in value.items():
                if depth == 0 and k in _SKIP_KEYS:
                    continue
                visit(f"{prefix}.{k}" if prefix else str(k), v, depth + 1)

    for k, v in report.items():
        if k in _SKIP_KEYS:
            continue
        if k == "rows" and isinstance(v, list):
            for row in v:
                if isinstance(row, dict) and "name" in row \
                        and isinstance(row.get("us_per_call"), (int, float)):
                    out[f"{row['name']}.us_per_call"] = float(
                        row["us_per_call"])
            continue
        visit(str(k), v, 1)
    return out


def history_path(out_dir: str, module: str) -> str:
    """``<out_dir>/history/<module>.jsonl``."""
    return os.path.join(out_dir, HISTORY_DIRNAME, f"{module}.jsonl")


def append_history(out_dir: str, module: str, report: dict,
                   ts: Optional[float] = None) -> Optional[str]:
    """Append one flattened snapshot of ``report`` to the module's history.

    Failed runs are NOT appended — a crash must not poison the rolling
    baseline. Returns the history path (None when nothing was written).
    """
    if report.get("failed"):
        return None
    metrics = flatten_metrics(report)
    if not metrics:
        return None
    path = history_path(out_dir, module)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    line = json.dumps(
        {"ts": time.time() if ts is None else ts, "metrics": metrics},
        separators=(",", ":"), sort_keys=True)
    with open(path, "a", encoding="utf-8") as f:
        f.write(line + "\n")
    return path


def load_history(out_dir: str, module: str) -> List[dict]:
    """All parseable history entries for ``module``, oldest first.

    Tolerant of truncated tail lines (a concurrent run may be mid-append).
    """
    path = history_path(out_dir, module)
    entries: List[dict] = []
    try:
        with open(path, encoding="utf-8") as f:
            lines = f.readlines()
    except OSError:
        return entries
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            entry = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(entry, dict) and isinstance(entry.get("metrics"), dict):
            entries.append(entry)
    entries.sort(key=lambda e: e.get("ts", 0.0))
    return entries


def list_modules(out_dir: str) -> List[str]:
    """Module names that have a history file under ``out_dir``."""
    hist_dir = os.path.join(out_dir, HISTORY_DIRNAME)
    try:
        names = os.listdir(hist_dir)
    except OSError:
        return []
    return sorted(
        os.path.splitext(n)[0] for n in names if n.endswith(".jsonl"))
