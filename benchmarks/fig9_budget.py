"""Fig. 9 / Tables 7-10: limited compute budgets. Residual norms and test
metrics for epoch budgets x estimator x warm-start, per solver.

Key paper claims checked:
  * residuals rise as the budget shrinks,
  * pathwise reaches lower residuals than standard at equal budget,
  * warm starting lowers residuals further (progress accumulates),
  * predictive quality correlates only weakly with residual norms.
"""
from __future__ import annotations

from benchmarks.common import bench_dataset, csv_line, run_variant


def main(small: bool = True):
    ds = bench_dataset("pol", max_n=800 if small else 4000)
    steps = 15 if small else 50
    budgets = (3, 10) if small else (10, 20, 50)
    for solver in ("cg", "ap", "sgd"):
        for budget in budgets:
            for pathwise in (False, True):
                for warm in (False, True):
                    r = run_variant(ds, solver, pathwise, warm, steps=steps,
                                    budget=float(budget))
                    name = (f"fig9/{solver}/b{budget}/"
                            f"{'path' if pathwise else 'std'}"
                            f"{'+warm' if warm else ''}")
                    csv_line(
                        name,
                        r["total_time_s"] * 1e6 / steps,
                        f"final_res_z={r['final_res_z']:.4f};"
                        f"mean_res_z={r['mean_res_z']:.4f};"
                        f"cum_epochs={r['cum_epochs'][-1]:.1f};"
                        f"llh={r.get('test_llh', float('nan')):.3f}",
                    )


if __name__ == "__main__":
    main()
