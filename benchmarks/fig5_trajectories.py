"""Figs. 5/8/11-13: hyperparameter-trajectory deviation from exact
(Cholesky) optimisation for all four estimator/warm-start variants.
Reports the max |delta| per hyperparameter over the trajectory — the
paper's histogram statistic.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_dataset, csv_line
from repro.core import (
    OuterConfig,
    exact_outer_step,
    init_outer_state,
    outer_step,
)
from repro.gp.hyperparams import HyperParams
from repro.solvers import SolverConfig
from repro.train.adam import AdamConfig, adam_init


def main(small: bool = True):
    ds = bench_dataset("pol", max_n=512 if small else 2000)
    x, y = ds.x_train, ds.y_train
    d = x.shape[1]
    steps = 12 if small else 40

    exact = []
    params = HyperParams.create(d)
    adam = adam_init(params)
    for _ in range(steps):
        params, adam, _ = exact_outer_step(params, adam, x, y,
                                           AdamConfig(learning_rate=0.1))
        exact.append(np.asarray(params.flat()))
    exact = np.stack(exact)

    for est in ("standard", "pathwise"):
        for warm in (False, True):
            cfg = OuterConfig(
                estimator=est, warm_start=warm, num_probes=64,
                num_rff_pairs=800,
                solver=SolverConfig(name="cg", tolerance=0.01,
                                    max_epochs=500, precond_rank=20),
                num_steps=steps, bm=256, bn=256,
            )
            st = init_outer_state(jax.random.PRNGKey(0), cfg, x)
            traj = []
            for _ in range(steps):
                st, m = outer_step(st, x, y, cfg)
                traj.append(np.asarray(m["hypers"]))
            traj = np.stack(traj)
            delta = np.abs(traj - exact)
            csv_line(
                f"fig5/{est}{'+warm' if warm else ''}",
                0.0,
                f"max_abs_delta={delta.max():.4f};"
                f"median_abs_delta={np.median(delta):.4f};"
                f"final_max_delta={np.abs(traj[-1]-exact[-1]).max():.4f}",
            )


if __name__ == "__main__":
    main()
