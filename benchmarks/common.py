"""Shared benchmark utilities: datasets, fit wrapper, timing, CSV output."""
from __future__ import annotations

import jax
import numpy as np

from repro.core import OuterConfig, fit
from repro.data.synthetic import load_dataset, pad_to_block_multiple
from repro.solvers import NO_EPOCH_BUDGET, SolverConfig


def bench_dataset(name="pol", max_n=800):
    return load_dataset(name, max_n=max_n)


def run_variant(
    ds,
    solver: str,
    pathwise: bool,
    warm: bool,
    steps: int = 20,
    probes: int = 32,
    budget: float = 0.0,
    block_size: int = 100,
    batch_size: int = 100,
    sgd_lr: float = 2.0,
    precond_rank: int = 20,
    tolerance: float = 0.01,
    seed: int = 0,
    eval_at_end: bool = True,
    record_history: int = 0,
    budget_policy=None,
):
    """One (solver x estimator x warm-start [x budget]) cell. Returns dict.

    ``budget <= 0`` means no per-step epoch budget (run each solve to
    tolerance — the explicit ``NO_EPOCH_BUDGET`` sentinel). ``budget_policy``
    (a ``repro.solvers.adaptive.BudgetPolicy``) switches the fit to adaptive
    per-step allocation; it requires ``record_history >= 2``. The returned
    dict carries cumulative epoch accounting: ``cum_epochs`` is the running
    total over steps (``cum_epochs[-1] == total_epochs``).
    """
    x, y = ds.x_train, ds.y_train
    if solver in ("ap", "sgd"):
        blk = block_size if solver == "ap" else batch_size
        x, y, _ = pad_to_block_multiple(x, y, blk)
    scfg = SolverConfig(
        name=solver, tolerance=tolerance,
        max_epochs=budget if budget > 0 else NO_EPOCH_BUDGET,
        precond_rank=precond_rank, block_size=block_size,
        batch_size=batch_size, learning_rate=sgd_lr,
        record_history=record_history,
    )
    cfg = OuterConfig(
        estimator="pathwise" if pathwise else "standard",
        warm_start=warm, num_probes=probes, num_rff_pairs=500,
        solver=scfg, num_steps=steps, bm=256, bn=256,
    )
    res = fit(x, y, cfg, key=jax.random.PRNGKey(seed),
              x_test=ds.x_test, y_test=ds.y_test,
              eval_every=steps if eval_at_end else 0,
              budget_policy=budget_policy)
    cum_epochs = np.cumsum(res.history["epochs"])
    out = {
        "solver": solver, "pathwise": pathwise, "warm": warm,
        "budget": budget,
        "total_time_s": res.wall_time_s,
        "total_epochs": float(cum_epochs[-1]),
        "cum_epochs": cum_epochs,
        "total_iters": int(res.history["iters"].sum()),
        "final_res_y": float(res.history["res_y"][-1]),
        "final_res_z": float(res.history["res_z"][-1]),
        "mean_res_z": float(res.history["res_z"].mean()),
        "hypers": res.history["hypers"],
        "res_z_per_step": res.history["res_z"],
        "iters_per_step": res.history["iters"],
    }
    if budget_policy is not None:
        out["budget_alloc_per_step"] = res.history["budget_alloc"]
        out["budget_pool_left"] = float(res.history["budget_pool"][-1])
    if eval_at_end and len(res.history["eval_llh"]):
        out["test_llh"] = float(res.history["eval_llh"][-1])
        out["test_rmse"] = float(res.history["eval_rmse"][-1])
    return out


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
