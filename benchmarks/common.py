"""Shared benchmark utilities: datasets, fit wrapper, timing, CSV output."""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import numpy as np

from repro.core import OuterConfig, fit
from repro.data.synthetic import load_dataset, pad_to_block_multiple
from repro.solvers import SolverConfig


def bench_dataset(name="pol", max_n=800):
    return load_dataset(name, max_n=max_n)


def run_variant(
    ds,
    solver: str,
    pathwise: bool,
    warm: bool,
    steps: int = 20,
    probes: int = 32,
    budget: float = 0.0,
    block_size: int = 100,
    batch_size: int = 100,
    sgd_lr: float = 2.0,
    precond_rank: int = 20,
    tolerance: float = 0.01,
    seed: int = 0,
    eval_at_end: bool = True,
):
    """One (solver x estimator x warm-start [x budget]) cell. Returns dict."""
    x, y = ds.x_train, ds.y_train
    if solver in ("ap", "sgd"):
        blk = block_size if solver == "ap" else batch_size
        x, y, _ = pad_to_block_multiple(x, y, blk)
    scfg = SolverConfig(
        name=solver, tolerance=tolerance,
        max_epochs=budget if budget > 0 else 1e9,
        precond_rank=precond_rank, block_size=block_size,
        batch_size=batch_size, learning_rate=sgd_lr,
    )
    cfg = OuterConfig(
        estimator="pathwise" if pathwise else "standard",
        warm_start=warm, num_probes=probes, num_rff_pairs=500,
        solver=scfg, num_steps=steps, bm=256, bn=256,
    )
    res = fit(x, y, cfg, key=jax.random.PRNGKey(seed),
              x_test=ds.x_test, y_test=ds.y_test,
              eval_every=steps if eval_at_end else 0)
    out = {
        "solver": solver, "pathwise": pathwise, "warm": warm,
        "budget": budget,
        "total_time_s": res.wall_time_s,
        "total_epochs": float(res.history["epochs"].sum()),
        "total_iters": int(res.history["iters"].sum()),
        "final_res_y": float(res.history["res_y"][-1]),
        "final_res_z": float(res.history["res_z"][-1]),
        "mean_res_z": float(res.history["res_z"].mean()),
        "hypers": res.history["hypers"],
        "res_z_per_step": res.history["res_z"],
        "iters_per_step": res.history["iters"],
    }
    if eval_at_end and len(res.history["eval_llh"]):
        out["test_llh"] = float(res.history["eval_llh"][-1])
        out["test_rmse"] = float(res.history["eval_rmse"][-1])
    return out


def csv_line(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
