"""Aggregate artifacts/dryrun/*.json into the EXPERIMENTS.md roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
prints a markdown table; ``--csv`` prints CSV instead.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_reports(dir: str, include_variants: bool = False):
    out = []
    for path in sorted(glob.glob(os.path.join(dir, "*.json"))):
        if os.path.basename(path).startswith("_"):
            continue
        with open(path) as f:
            r = json.load(f)
        if r.get("variant") and not include_variants:
            continue  # hillclimb variants live in EXPERIMENTS.md §Perf
        out.append(r)
    return out


def fmt_ms(s):
    return f"{s*1e3:.2f}"


def markdown_table(reports, mesh="single"):
    rows = [r for r in reports if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    hdr = ("| arch | shape | t_compute (ms) | t_memory (ms) | t_coll (ms) | "
           "bottleneck | GiB/chip | MODEL_FLOPS/HLO | roofline frac |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute'])} | "
            f"{fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} | "
            f"{r['bottleneck']} | {r['peak_bytes']/2**30:.2f} | "
            f"{r['useful_fraction']:.3f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args(argv)
    reports = load_reports(args.dir)
    if not reports:
        print(f"(no dry-run reports under {args.dir})")
        return
    if args.csv:
        for r in reports:
            if r["mesh"] != args.mesh:
                continue
            print(f"roofline/{r['arch']}/{r['shape']},0.0,"
                  f"bottleneck={r['bottleneck']};"
                  f"t_comp_ms={r['t_compute']*1e3:.3f};"
                  f"t_mem_ms={r['t_memory']*1e3:.3f};"
                  f"t_coll_ms={r['t_collective']*1e3:.3f};"
                  f"gib={r['peak_bytes']/2**30:.2f};"
                  f"useful={r['useful_fraction']:.3f};"
                  f"roofline={r['roofline_fraction']:.3f}")
    else:
        print(markdown_table(reports, args.mesh))


if __name__ == "__main__":
    main()
