"""Serving throughput: bucketed engine vs. the old per-request loop.

Measures, at a fixed request width (default 64 rows):

  * the SEED per-request path (eager `pathwise_predict` per request, no jit
    — what `launch/serve.py::serve_gp` did before the engine existed),
  * the COMPAT path (jit hoisted out of the loop, tail padded — the minimal
    fix kept in `serve_gp_compat`),
  * the bucketed ENGINE (shape buckets, warmup, zero steady-state retraces),

reporting q/s and p50/p99 latency, asserting the engine's >= 5x speedup over
the seed path and zero retraces after warmup (jit cache-size check), and
finally comparing warm- vs cold-started online refresh after appending 256
observations (warm must converge in fewer solver epochs).

Run: PYTHONPATH=src python benchmarks/serve_throughput.py [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import OuterConfig, fit, pathwise_predict
from repro.data.synthetic import load_dataset
from repro.serve import BucketedEngine, OnlineGP, export_servable
from repro.solvers import SolverConfig


def _timed_loop(fn, requests, make_query):
    lat = []
    t0 = time.perf_counter()
    for i in range(requests):
        xq = make_query(i)
        ts = time.perf_counter()
        out = fn(xq)
        jax.block_until_ready(out.mean)
        lat.append(time.perf_counter() - ts)
    dt = time.perf_counter() - t0
    lat_ms = np.asarray(lat) * 1e3
    return dt, float(np.percentile(lat_ms, 50)), float(np.percentile(lat_ms, 99))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="pol")
    ap.add_argument("--max-n", type=int, default=2000)
    ap.add_argument("--train-steps", type=int, default=5)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--width", type=int, default=64)
    ap.add_argument("--append", type=int, default=256)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes for CI smoke")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.quick:
        args.max_n, args.train_steps, args.requests, args.append = 600, 2, 10, 64

    ds = load_dataset(args.dataset, max_n=args.max_n)
    cfg = OuterConfig(
        estimator="pathwise", warm_start=True, num_probes=16,
        num_rff_pairs=256,
        solver=SolverConfig(name="cg", max_epochs=100, precond_rank=0),
        num_steps=args.train_steps, bm=512, bn=512,
    )
    # Hold out the appended rows so the refresh comparison sees fresh data.
    n_fit = ds.x_train.shape[0] - args.append
    x_fit, y_fit = ds.x_train[:n_fit], ds.y_train[:n_fit]
    res = fit(x_fit, y_fit, cfg, key=jax.random.PRNGKey(args.seed))
    state = res.state
    width, n_test = args.width, ds.x_test.shape[0]

    def query(i):
        lo = (i * width) % max(1, n_test - width)
        return ds.x_test[lo : lo + width]

    # -- seed path: eager pathwise_predict per request (pre-engine behaviour)
    def seed_predict(xq):
        return pathwise_predict(x_fit, xq, state.carry_v, state.probes,
                                state.params, bm=cfg.bm, bn=cfg.bn)

    seed_dt, seed_p50, seed_p99 = _timed_loop(seed_predict, args.requests, query)

    # -- compat path: jit hoisted once (launch.serve.serve_gp_compat fix)
    from functools import partial

    compat = jax.jit(partial(pathwise_predict, bm=cfg.bm, bn=cfg.bn))
    compat_fn = lambda xq: compat(x_fit, xq, state.carry_v, state.probes,
                                  state.params)
    compat_fn(query(0))  # compile outside the timed loop
    compat_dt, compat_p50, compat_p99 = _timed_loop(
        compat_fn, args.requests, query
    )

    # -- bucketed engine
    buckets = (width // 2, width) if args.quick else (16, width, 4 * width)
    model = export_servable(state, x_fit)
    engine = BucketedEngine(model, buckets=buckets, bm=cfg.bm, bn=cfg.bn)
    compiles = engine.warmup()
    eng_dt, eng_p50, eng_p99 = _timed_loop(engine.submit, args.requests, query)
    now = engine.num_compiles()
    retraces = None if (compiles is None or now is None) else now - compiles

    import json

    qps = lambda dt: args.requests * width / dt
    print(f"[serve-bench] width={width} requests={args.requests} "
          f"n={n_fit} buckets={buckets}")
    print(f"  seed   : {qps(seed_dt):9.1f} q/s  p50={seed_p50:7.2f}ms "
          f"p99={seed_p99:7.2f}ms")
    print(f"  compat : {qps(compat_dt):9.1f} q/s  p50={compat_p50:7.2f}ms "
          f"p99={compat_p99:7.2f}ms")
    print(f"  engine : {qps(eng_dt):9.1f} q/s  p50={eng_p50:7.2f}ms "
          f"p99={eng_p99:7.2f}ms  retraces={retraces}")
    # the shared stats wire format (same shape as GET /stats "engine")
    print(f"  stats  : {json.dumps(engine.stats_dict())}")
    speedup = seed_dt / eng_dt
    print(f"  engine speedup over seed path: {speedup:.1f}x")
    if retraces is None:
        print("  WARNING: jit cache introspection unavailable; "
              "zero-retrace contract NOT verified")
    else:
        assert retraces == 0, f"steady-state serving retraced {retraces}x"
    if not args.quick:
        assert speedup >= 5.0, f"engine only {speedup:.1f}x over seed path"

    # -- online refresh: warm vs cold epochs on an appended block ----------
    # Tighter tolerance than the fit so epoch counts resolve the warm-start
    # advantage (at tau=0.01 both paths can round to the same epoch count).
    from dataclasses import replace

    refresh_cfg = replace(cfg, solver=replace(cfg.solver, tolerance=1e-4))
    # Tiny problems converge in so few epochs that integer epoch counts
    # cannot resolve the warm-start gain; compare residuals at a fixed
    # 1-epoch budget there instead.
    budget = 1.0 if args.quick else None
    x_new = ds.x_train[n_fit : n_fit + args.append]
    y_new = ds.y_train[n_fit : n_fit + args.append]
    reports = {}
    for warm in (True, False):
        online = OnlineGP(x_fit, y_fit, state, refresh_cfg)
        online.append(x_new, y_new)
        reports[warm] = online.refine(budget_epochs=budget, warm=warm,
                                      mode="solve")
    w, c = reports[True], reports[False]
    print(f"  refresh(+{args.append}): warm {w.epochs:.0f} epochs "
          f"(res_y={w.res_y:.2e}) vs cold {c.epochs:.0f} epochs "
          f"(res_y={c.res_y:.2e})")
    if args.quick:
        assert w.res_y < c.res_y, (
            f"warm refresh residual ({w.res_y}) not below cold ({c.res_y}) "
            f"at a {budget}-epoch budget"
        )
    else:
        assert w.epochs < c.epochs, (
            f"warm refresh ({w.epochs}) not cheaper than cold ({c.epochs})"
        )
    print("[serve-bench] OK")


if __name__ == "__main__":
    main()
